"""Minimal pure-JAX optimizers (no optax in this environment).

API mirrors optax: ``opt = adamw(...); state = opt.init(params);
updates, state = opt.update(grads, state, params); params = apply(params,
updates)`` — kept as plain pytrees so they shard transparently under pjit
(optimizer state inherits the parameter sharding rules in ``repro.dist``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class Optimizer(NamedTuple):
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, Optional[PyTree]], Tuple[PyTree, PyTree]]


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def _to_schedule(lr) -> Callable[[jax.Array], jax.Array]:
    if callable(lr):
        return lr
    return lambda step: jnp.asarray(lr, jnp.float32)


def sgd(lr, momentum: float = 0.0) -> Optimizer:
    sched = _to_schedule(lr)

    def init(params):
        mu = (jax.tree.map(jnp.zeros_like, params) if momentum else None)
        return {"step": jnp.zeros((), jnp.int32), "mu": mu}

    def update(grads, state, params=None):
        step = state["step"] + 1
        lr_t = sched(step)
        if momentum:
            mu = jax.tree.map(lambda m, g: momentum * m + g, state["mu"], grads)
            upd = jax.tree.map(lambda m: -lr_t * m, mu)
            return upd, {"step": step, "mu": mu}
        upd = jax.tree.map(lambda g: -lr_t * g, grads)
        return upd, {"step": step, "mu": None}

    return Optimizer(init, update)


def adam(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8) -> Optimizer:
    return adamw(lr, b1=b1, b2=b2, eps=eps, weight_decay=0.0)


def adamw(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    sched = _to_schedule(lr)

    def init(params):
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        }

    def update(grads, state, params=None):
        step = state["step"] + 1
        lr_t = sched(step)
        g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], g32)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], g32)
        c1 = 1 - b1 ** step.astype(jnp.float32)
        c2 = 1 - b2 ** step.astype(jnp.float32)

        def upd_leaf(m_, v_, p):
            u = -lr_t * (m_ / c1) / (jnp.sqrt(v_ / c2) + eps)
            if weight_decay and p is not None:
                u = u - lr_t * weight_decay * p.astype(jnp.float32)
            return u

        if params is None:
            upd = jax.tree.map(lambda m_, v_: upd_leaf(m_, v_, None), m, v)
        else:
            upd = jax.tree.map(upd_leaf, m, v, params)
        return upd, {"step": step, "m": m, "v": v}

    return Optimizer(init, update)


def clip_by_global_norm(grads: PyTree, max_norm: float) -> Tuple[PyTree, jax.Array]:
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-12))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), gnorm


def cosine_warmup_schedule(peak_lr: float, warmup_steps: int,
                           total_steps: int, min_ratio: float = 0.1):
    def sched(step):
        step = step.astype(jnp.float32)
        warm = step / jnp.maximum(1.0, warmup_steps)
        prog = jnp.clip((step - warmup_steps)
                        / jnp.maximum(1.0, total_steps - warmup_steps), 0, 1)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return peak_lr * jnp.minimum(warm, cos)
    return sched
