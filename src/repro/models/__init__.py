from .lm import (ModelConfig, forward, init_decode_cache, init_params,
                 loss_fn, make_prefill_step, make_serve_step,
                 make_train_step, model_flops_per_token, active_param_count,
                 param_count)
from .layers import KVCache, attention, decode_attention, rms_norm, rope

__all__ = [
    "ModelConfig", "forward", "init_decode_cache", "init_params", "loss_fn",
    "make_prefill_step", "make_serve_step", "make_train_step",
    "model_flops_per_token", "active_param_count", "param_count",
    "KVCache", "attention", "decode_attention", "rms_norm", "rope",
]
