"""Unified LM substrate: one ``ModelConfig`` covers all 10 assigned
architectures (dense / MoE / SWA / hybrid-SSM / RWKV / audio / VLM stubs).

Layers are stacked ([L, ...] leading axis on every weight) and iterated with
``jax.lax.scan`` so the lowered HLO contains a single layer body — essential
for the 512-device dry-run compile times.  Zamba-style hybrids scan over
"super-blocks" (``mamba_per_attn`` Mamba-2 layers + one application of the
*shared* attention block) with the shared weights closed over.

Entry points (all pure functions, pjit-able):
  * ``init_params(key, cfg)``          — real init (smoke tests)
  * ``forward(params, cfg, batch)``    — training/prefill logits (+caches)
  * ``loss_fn`` / ``make_train_step``  — next-token CE + AdamW update
  * ``init_decode_cache`` / ``make_serve_step`` — one-token decode against
    stacked per-layer caches (KV ring-buffer for SWA, SSM/RWKV states).
"""
from __future__ import annotations

import dataclasses
import functools
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist import (constrain, constrain_act, constrain_act_serve,
                        constrain_proj)
from repro.optim.optimizers import Optimizer, apply_updates

from . import moe as moe_lib
from . import rwkv as rwkv_lib
from . import ssm as ssm_lib
from .layers import (KVCache, attention, decode_attention, gelu_mlp,
                     init_linear, init_rms, prefill_into_cache, rms_norm,
                     rope, swiglu)

Params = Dict[str, Any]

__all__ = ["ModelConfig", "init_params", "forward", "loss_fn",
           "make_train_step", "make_serve_step", "init_decode_cache",
           "param_count", "model_flops_per_token"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    kind: str                   # 'dense' | 'moe' | 'rwkv' | 'zamba'
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0
    moe_experts: int = 0
    moe_top_k: int = 0
    capacity_factor: float = 1.25
    window: int = 0             # sliding-window size (0 = full attention)
    ssm_state: int = 64
    ssm_head_dim: int = 64
    mamba_per_attn: int = 6     # zamba: mamba layers per shared-attn site
    mlp: str = "swiglu"         # 'swiglu' | 'gelu'
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    frontend: str = "none"      # 'none' | 'audio_stub' | 'vision_stub'
    vision_tokens: int = 256    # prefix length for the vision stub
    remat: bool = True
    q_block: int = 512
    attn_impl: str = "blocked"   # 'blocked' | 'flash' (Pallas kernel)

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        return -(-self.vocab // 128) * 128

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run long_500k? (SSM/hybrid/linear-attn or SWA)."""
        return self.kind in ("rwkv", "zamba") or self.window > 0

    def zamba_structure(self) -> Tuple[int, int, int]:
        """(n_sites, mamba_per_site, n_tail) with all layers Mamba except
        the shared attention applied after every ``mamba_per_attn``."""
        per = self.mamba_per_attn
        sites = self.n_layers // per
        tail = self.n_layers - sites * per
        return sites, per, tail


def param_count(params: Params) -> int:
    return sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))


def model_flops_per_token(cfg: ModelConfig) -> float:
    """6·N_active per token (the §Roofline MODEL_FLOPS convention)."""
    n = active_param_count(cfg)
    return 6.0 * n


def active_param_count(cfg: ModelConfig) -> int:
    """Parameters touched per token (MoE counts top_k experts only)."""
    d, hd = cfg.d_model, cfg.hd
    attn = d * hd * (cfg.n_heads * 2 + cfg.n_kv * 2)
    if cfg.mlp == "swiglu":
        ffn = 3 * d * cfg.d_ff
    else:
        ffn = 2 * d * cfg.d_ff
    if cfg.kind == "moe":
        per_layer = attn + cfg.moe_top_k * ffn + d * cfg.moe_experts
    elif cfg.kind == "dense":
        per_layer = attn + ffn
    elif cfg.kind == "rwkv":
        # time-mix: w_r/w_k/w_v/w_g/w_o (5·d²) + decay LoRA; channel-mix:
        # c_k [d,ff] + c_v [ff,d] + c_r [d,d]
        per_layer = 6 * d * d + 2 * d * cfg.d_ff + 2 * d * 64
    elif cfg.kind == "zamba":
        d_inner = 2 * d
        mamba = d * (2 * d_inner + 2 * cfg.ssm_state +
                     d_inner // cfg.ssm_head_dim) + d_inner * d
        sites, per, tail = cfg.zamba_structure()
        total = (sites * per + tail) * mamba + sites * 0
        shared = attn + 3 * d * cfg.d_ff
        return total + shared + 2 * cfg.vocab * d
    else:
        raise ValueError(cfg.kind)
    return cfg.n_layers * per_layer + 2 * cfg.vocab * d


# ====================================================================== init


def _init_attn(key, cfg: ModelConfig, dtype) -> Params:
    ks = jax.random.split(key, 4)
    d, hd = cfg.d_model, cfg.hd
    return {
        "ln1": init_rms(d, dtype),
        "wq": init_linear(ks[0], d, cfg.n_heads * hd, dtype),
        "wk": init_linear(ks[1], d, cfg.n_kv * hd, dtype),
        "wv": init_linear(ks[2], d, cfg.n_kv * hd, dtype),
        "wo": init_linear(ks[3], cfg.n_heads * hd, d, dtype),
    }


def _init_ffn(key, cfg: ModelConfig, dtype) -> Params:
    ks = jax.random.split(key, 3)
    d, f = cfg.d_model, cfg.d_ff
    if cfg.mlp == "swiglu":
        return {"ln2": init_rms(d, dtype),
                "w1": init_linear(ks[0], d, f, dtype),
                "w3": init_linear(ks[1], d, f, dtype),
                "w2": init_linear(ks[2], f, d, dtype)}
    return {"ln2": init_rms(d, dtype),
            "w1": init_linear(ks[0], d, f, dtype),
            "w2": init_linear(ks[1], f, d, dtype)}


def _init_layer(key, cfg: ModelConfig, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    if cfg.kind == "dense":
        return {**_init_attn(k1, cfg, dtype), **_init_ffn(k2, cfg, dtype)}
    if cfg.kind == "moe":
        p = _init_attn(k1, cfg, dtype)
        p["ln2"] = init_rms(cfg.d_model, dtype)
        p["moe"] = moe_lib.init_moe_params(k2, cfg.d_model, cfg.d_ff,
                                           cfg.moe_experts, dtype)
        return p
    if cfg.kind == "rwkv":
        p = rwkv_lib.init_rwkv_params(k1, cfg.d_model, cfg.d_ff,
                                      head_dim=cfg.hd, dtype=dtype)
        p["ln1"] = init_rms(cfg.d_model, dtype)
        p["ln2"] = init_rms(cfg.d_model, dtype)
        return p
    if cfg.kind == "zamba":  # one mamba layer
        p = ssm_lib.init_mamba_params(k1, cfg.d_model, cfg.ssm_state,
                                      head_dim=cfg.ssm_head_dim, dtype=dtype)
        p["ln"] = init_rms(cfg.d_model, dtype)
        return p
    raise ValueError(cfg.kind)


def init_params(key: jax.Array, cfg: ModelConfig) -> Params:
    dtype = cfg.jdtype
    k_embed, k_head, k_layers, k_shared = jax.random.split(key, 4)
    params: Params = {
        "embed": init_linear(k_embed, cfg.vocab_padded, cfg.d_model, dtype,
                             std=0.02),
        "final_norm": init_rms(cfg.d_model, dtype),
        "lm_head": init_linear(k_head, cfg.d_model, cfg.vocab_padded, dtype),
    }
    if cfg.kind == "zamba":
        sites, per, tail = cfg.zamba_structure()
        keys = jax.random.split(k_layers, sites * per)
        stacked = jax.vmap(lambda k: _init_layer(k, cfg, dtype))(keys)
        params["layers"] = jax.tree.map(
            lambda p: p.reshape(sites, per, *p.shape[1:]), stacked)
        if tail:
            tkeys = jax.random.split(jax.random.fold_in(k_layers, 7), tail)
            params["tail"] = jax.vmap(
                lambda k: _init_layer(k, cfg, dtype))(tkeys)
        ka, kf = jax.random.split(k_shared)
        params["shared_attn"] = {**_init_attn(ka, cfg, dtype),
                                 **_init_ffn(kf, cfg, dtype)}
    else:
        keys = jax.random.split(k_layers, cfg.n_layers)
        params["layers"] = jax.vmap(
            lambda k: _init_layer(k, cfg, dtype))(keys)
    return params


# ================================================================= block fwd


def _attn_apply(cfg: ModelConfig, lp: Params, x: jax.Array, pos0: int,
                collect_kv: bool):
    b, s, d = x.shape
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    q = constrain_proj(h @ lp["wq"], cfg.n_heads
                       ).reshape(b, s, cfg.n_heads, cfg.hd)
    k = constrain_proj(h @ lp["wk"], cfg.n_kv
                       ).reshape(b, s, cfg.n_kv, cfg.hd)
    v = constrain_proj(h @ lp["wv"], cfg.n_kv
                       ).reshape(b, s, cfg.n_kv, cfg.hd)
    positions = pos0 + jnp.arange(s)
    q = rope(q, positions[None], cfg.rope_theta)
    k = rope(k, positions[None], cfg.rope_theta)
    o = attention(q, k, v, window=cfg.window, q_block=cfg.q_block,
                  pos0=pos0, impl=cfg.attn_impl)
    o = o.reshape(b, s, cfg.n_heads * cfg.hd)
    o = constrain(o, ("pod", "data"), None, "model")
    x = x + o @ lp["wo"]
    return (x, (k, v)) if collect_kv else (x, None)


def _ffn_apply(cfg: ModelConfig, lp: Params, x: jax.Array):
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    if cfg.kind == "moe":
        y, aux = moe_lib.moe_ffn(h, lp["moe"], top_k=cfg.moe_top_k,
                                 capacity_factor=cfg.capacity_factor)
        return x + y, aux
    if cfg.mlp == "swiglu":
        return x + swiglu(h, lp["w1"], lp["w3"], lp["w2"]), 0.0
    return x + gelu_mlp(h, lp["w1"], lp["w2"]), 0.0


def _block_fwd(cfg: ModelConfig, lp: Params, x: jax.Array, pos0: int,
               collect_kv: bool = False):
    """One layer forward; returns (x, aux, kv-or-None).

    Block boundaries carry a sequence-sharded activation constraint
    (``constrain_act``): the [B,S,d] tensors the scan backward saves per
    layer are sharded over batch AND (seq × model), keeping remat
    residuals at 1/(dp·tp) of global size.
    """
    if cfg.kind in ("dense", "moe"):
        x, kv = _attn_apply(cfg, lp, x, pos0, collect_kv)
        x, aux = _ffn_apply(cfg, lp, x)
        return constrain_act(x), aux, kv
    if cfg.kind == "rwkv":
        x = rwkv_lib.rwkv_forward(lp, x, lp["ln1"], lp["ln2"], cfg.hd)
        return constrain_act(x), 0.0, None
    if cfg.kind == "zamba":  # single mamba layer
        y = ssm_lib.mamba_forward(lp, rms_norm(x, lp["ln"], cfg.norm_eps),
                                  d_state=cfg.ssm_state,
                                  head_dim=cfg.ssm_head_dim)
        return constrain_act(x + y), 0.0, None
    raise ValueError(cfg.kind)


# ==================================================================== forward


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _grad_cast(x, dtype):
    """Identity whose COTANGENT is cast to ``dtype``.

    The loss computes in f32, so ``d_logits @ lm_head.T`` promotes the
    backward activation stream to f32, which then flows f32 through every
    layer of the scan — doubling backward collective/HBM traffic.  This
    barrier keeps the backward stream in the forward compute dtype (bf16),
    i.e. standard mixed-precision backward.
    """
    return x


def _grad_cast_fwd(x, dtype):
    return x, None


def _grad_cast_bwd(dtype, _, g):
    return (g.astype(dtype),)


_grad_cast.defvjp(_grad_cast_fwd, _grad_cast_bwd)


def _embed_inputs(params: Params, cfg: ModelConfig, batch: Dict[str, Any]
                  ) -> jax.Array:
    if "embeds" in batch:                       # audio stub: frame embeddings
        x = batch["embeds"].astype(cfg.jdtype)
    else:
        tokens = batch["tokens"]
        x = params["embed"][tokens]
    if cfg.frontend == "vision_stub" and "vision_embeds" in batch:
        x = jnp.concatenate(
            [batch["vision_embeds"].astype(cfg.jdtype), x], axis=1)
    return constrain_act(x)


def forward(params: Params, cfg: ModelConfig, batch: Dict[str, Any],
            return_cache: bool = False):
    """Training / prefill forward.  Returns (logits, aux, caches|None)."""
    x = _embed_inputs(params, cfg, batch)

    def dense_body(x, lp):
        xo, aux, kv = _block_fwd(cfg, lp, x, 0, collect_kv=return_cache)
        return xo, (aux, kv)

    body = jax.checkpoint(dense_body) if (cfg.remat and not return_cache) \
        else dense_body

    caches = None
    if cfg.kind == "zamba":
        sites, per, tail = cfg.zamba_structure()

        def super_body(x, lp_site):
            def inner(xc, lp):
                xo, _, _ = _block_fwd(cfg, lp, xc, 0)
                return xo, None
            x, _ = jax.lax.scan(inner, x, lp_site)
            x, kv = _attn_apply(cfg, params["shared_attn"], x, 0,
                                return_cache)
            x, _ = _ffn_apply(cfg, params["shared_attn"], x)
            return constrain_act(x), kv
        sbody = jax.checkpoint(super_body) if (cfg.remat and not return_cache
                                               ) else super_body
        x, kvs = jax.lax.scan(sbody, x, params["layers"])
        if tail:
            def tail_body(xc, lp):
                xo, _, _ = _block_fwd(cfg, lp, xc, 0)
                return xo, None
            x, _ = jax.lax.scan(tail_body, x, params["tail"])
        aux = jnp.zeros((), jnp.float32)
        if return_cache:
            caches = {"attn_kv": kvs}
    else:
        x, (auxs, kvs) = jax.lax.scan(body, x, params["layers"])
        aux = jnp.sum(auxs) if cfg.kind == "moe" else jnp.zeros((), jnp.float32)
        if return_cache and cfg.kind in ("dense", "moe"):
            caches = {"attn_kv": kvs}

    x = _grad_cast(x, cfg.jdtype)   # keep the backward stream in bf16
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ params["lm_head"]
    logits = constrain(logits, ("pod", "data"), None, "model")
    return logits, aux, caches


def _mask_padded(logits: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.vocab_padded == cfg.vocab:
        return logits
    neg = jnp.full((cfg.vocab_padded - cfg.vocab,), -1e30, logits.dtype)
    return logits.at[..., cfg.vocab:].set(neg)


def loss_fn(params: Params, cfg: ModelConfig, batch: Dict[str, Any]
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    logits, aux, _ = forward(params, cfg, batch)
    labels = batch["labels"]
    if cfg.frontend == "vision_stub" and "vision_embeds" in batch:
        # prefix positions carry no labels
        nvis = batch["vision_embeds"].shape[1]
        logits = logits[:, nvis:]
    logits = _mask_padded(logits, cfg).astype(jnp.float32)
    shift_logits = logits[:, :-1]
    shift_labels = labels[:, 1:]
    logz = jax.nn.logsumexp(shift_logits, axis=-1)
    gold = jnp.take_along_axis(shift_logits,
                               shift_labels[..., None].astype(jnp.int32),
                               axis=-1)[..., 0]
    mask = (shift_labels >= 0).astype(jnp.float32)
    nll = ((logz - gold) * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    loss = nll + 0.01 * aux
    return loss, {"nll": nll, "aux": aux,
                  "tokens": mask.sum()}


def make_train_step(cfg: ModelConfig, optimizer: Optimizer,
                    microbatches: int = 1):
    """Build the jit-able train step.

    ``microbatches > 1`` enables gradient accumulation: the global batch is
    split on the leading axis and scanned, so only one microbatch's
    activations are live at a time (this is what fits the biggest
    (arch × shape) cells into 16 GB/chip).  Gradients accumulate in f32;
    semantics are identical to the single-shot step (property-tested).
    """

    def single(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, cfg, batch)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        metrics = dict(metrics, loss=loss)
        return params, opt_state, metrics

    if microbatches <= 1:
        return single

    def accumulated(params, opt_state, batch):
        n = microbatches
        mb = jax.tree.map(
            lambda a: a.reshape(n, a.shape[0] // n, *a.shape[1:]), batch)

        def body(acc, one):
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, cfg, one)
            acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), acc, grads)
            return acc, dict(metrics, loss=loss)

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        gsum, ms = jax.lax.scan(body, zeros, mb)
        grads = jax.tree.map(lambda g: (g / n), gsum)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        metrics = jax.tree.map(lambda m: m.mean(), ms)
        return params, opt_state, metrics

    return accumulated


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        logits, _, caches = forward(params, cfg, batch, return_cache=True)
        return _mask_padded(logits[:, -1:], cfg), caches
    return prefill_step


# ===================================================================== decode


def init_decode_cache(cfg: ModelConfig, batch: int, seq_len: int):
    """Stacked per-layer cache pytree for one-token decode.

    Attention layers: KV ring buffer of min(seq_len, window or inf);
    Mamba layers: (conv, state); RWKV: (shift, wkv state).
    """
    dtype = cfg.jdtype
    cap = min(seq_len, cfg.window) if cfg.window else seq_len
    if cfg.kind in ("dense", "moe"):
        return {"attn": KVCache.init(batch, cap, cfg.n_kv, cfg.hd, dtype,
                                     prefix=(cfg.n_layers,))}
    if cfg.kind == "rwkv":
        c = rwkv_lib.init_rwkv_cache(batch, cfg.d_model, cfg.hd, dtype)
        return {"rwkv": jax.tree.map(
            lambda l: jnp.broadcast_to(l, (cfg.n_layers, *l.shape)), c)}
    if cfg.kind == "zamba":
        sites, per, tail = cfg.zamba_structure()
        mc = ssm_lib.init_mamba_cache(batch, cfg.d_model, cfg.ssm_state,
                                      cfg.ssm_head_dim, dtype=dtype)
        out = {"mamba": jax.tree.map(
            lambda l: jnp.broadcast_to(l, (sites, per, *l.shape)), mc),
            "attn": KVCache.init(batch, cap, cfg.n_kv, cfg.hd, dtype,
                                 prefix=(sites,))}
        if tail:
            out["mamba_tail"] = jax.tree.map(
                lambda l: jnp.broadcast_to(l, (tail, *l.shape)), mc)
        return out
    raise ValueError(cfg.kind)


def _attn_step(cfg: ModelConfig, lp: Params, cache: KVCache, x: jax.Array):
    b, s, d = x.shape
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    q = (h @ lp["wq"]).reshape(b, s, cfg.n_heads, cfg.hd)
    k = (h @ lp["wk"]).reshape(b, s, cfg.n_kv, cfg.hd)
    v = (h @ lp["wv"]).reshape(b, s, cfg.n_kv, cfg.hd)
    pos = cache.pos[None, None]
    q = rope(q, pos, cfg.rope_theta)
    k = rope(k, pos, cfg.rope_theta)
    o, cache = decode_attention(q, k, v, cache, window=cfg.window)
    x = x + o.reshape(b, s, cfg.n_heads * cfg.hd) @ lp["wo"]
    return x, cache


def make_serve_step(cfg: ModelConfig):
    """Returns serve_step(params, cache, tokens[B,1]) -> (logits, cache)."""

    def serve_step(params, cache, batch):
        tokens = batch["tokens"]
        x = constrain_act_serve(params["embed"][tokens])

        if cfg.kind in ("dense", "moe"):
            def body(x, xs):
                lp, c = xs
                x, c = _attn_step(cfg, lp, c, x)
                x, _ = _ffn_apply(cfg, lp, x)
                return constrain_act_serve(x), c
            x, new_attn = jax.lax.scan(body, x,
                                       (params["layers"], cache["attn"]))
            new_cache = {"attn": new_attn}
        elif cfg.kind == "rwkv":
            def body(x, xs):
                lp, c = xs
                x, c = rwkv_lib.rwkv_step(lp, c, x, lp["ln1"], lp["ln2"],
                                          cfg.hd)
                return x, c
            x, new_rwkv = jax.lax.scan(body, x,
                                       (params["layers"], cache["rwkv"]))
            new_cache = {"rwkv": new_rwkv}
        elif cfg.kind == "zamba":
            sites, per, tail = cfg.zamba_structure()

            def super_body(x, xs):
                lp_site, mcache, acache = xs

                def inner(carry, xs2):
                    xc = carry
                    lp, mc = xs2
                    y, mc = ssm_lib.mamba_step(
                        lp, mc, rms_norm(xc, lp["ln"], cfg.norm_eps),
                        d_state=cfg.ssm_state, head_dim=cfg.ssm_head_dim)
                    return xc + y, mc
                x, mcache = jax.lax.scan(inner, x, (lp_site, mcache))
                x, acache = _attn_step(cfg, params["shared_attn"], acache, x)
                x, _ = _ffn_apply(cfg, params["shared_attn"], x)
                return x, (mcache, acache)
            x, (new_m, new_a) = jax.lax.scan(
                super_body, x,
                (params["layers"], cache["mamba"], cache["attn"]))
            new_cache = {"mamba": new_m, "attn": new_a}
            if tail:
                def tail_body(x, xs):
                    lp, mc = xs
                    y, mc = ssm_lib.mamba_step(
                        lp, mc, rms_norm(x, lp["ln"], cfg.norm_eps),
                        d_state=cfg.ssm_state, head_dim=cfg.ssm_head_dim)
                    return x + y, mc
                x, new_t = jax.lax.scan(tail_body, x,
                                        (params["tail"],
                                         cache["mamba_tail"]))
                new_cache["mamba_tail"] = new_t
        else:
            raise ValueError(cfg.kind)

        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = _mask_padded(x @ params["lm_head"], cfg)
        return logits, new_cache

    return serve_step
