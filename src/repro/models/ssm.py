"""Mamba-2 (SSD) block — the state-space half of Zamba2 (arXiv:2411.15242,
arXiv:2405.21060).

Scalar-per-head decay ``a_t = exp(-exp(A_log)·dt_t)``; state update
``h_t = a_t h_{t-1} + (dt_t B_t) x_t``; output ``y_t = C_t·h_t + D x_t``.

Training uses the chunked SSD decomposition (chunk length Q): intra-chunk
attention-like term + inter-chunk state carried by a ``lax.scan`` over
chunks, so peak memory is O(S·Q) per head instead of O(S·state) per step —
matching how Mamba-2 is actually trained.  ``mamba_step`` is the O(1)
recurrent form used for decode (``long_500k`` runs at constant memory).
Equivalence chunked == sequential is property-tested.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .layers import rms_norm

__all__ = ["MambaParams", "init_mamba_params", "mamba_forward", "mamba_step",
           "MambaCache", "init_mamba_cache"]

Params = Dict[str, jax.Array]

_CONV_K = 4  # depthwise causal conv width


def init_mamba_params(key: jax.Array, d_model: int, d_state: int,
                      head_dim: int = 64, expand: int = 2,
                      dtype=jnp.float32) -> Params:
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    conv_dim = d_inner + 2 * d_state
    ks = jax.random.split(key, 4)
    s_in = d_model ** -0.5
    proj_out = 2 * d_inner + 2 * d_state + n_heads   # z, x, B, C, dt
    return {
        "in_proj": (jax.random.normal(ks[0], (d_model, proj_out), jnp.float32)
                    * s_in).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (conv_dim, _CONV_K), jnp.float32)
                   * 0.2).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)).astype(jnp.float32),
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "norm": jnp.ones((d_inner,), dtype),
        "out_proj": (jax.random.normal(ks[2], (d_inner, d_model), jnp.float32)
                     * (d_inner ** -0.5)).astype(dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv, width 4, via shifted adds.  x: [B, S, C]."""
    out = x * w[None, None, :, -1]
    for i in range(1, _CONV_K):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, :-i or None][:, :x.shape[1]]
        out = out + shifted * w[None, None, :, -1 - i]
    return out + b


def _split_proj(zxbcdt: jax.Array, d_inner: int, d_state: int, n_heads: int):
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner:d_inner + d_inner + 2 * d_state]
    dt = zxbcdt[..., -n_heads:]
    return z, xbc, dt


def mamba_forward(params: Params, x: jax.Array, *, d_state: int,
                  head_dim: int = 64, chunk: int = 128) -> jax.Array:
    """x: [B, S, D] -> [B, S, D] (training / prefill path, chunked SSD)."""
    b, s, d = x.shape
    d_inner = params["out_proj"].shape[0]
    n_heads = d_inner // head_dim

    zxbcdt = x @ params["in_proj"].astype(x.dtype)
    z, xbc, dt = _split_proj(zxbcdt, d_inner, d_state, n_heads)
    xbc = jax.nn.silu(_causal_conv(xbc, params["conv_w"].astype(x.dtype),
                                   params["conv_b"].astype(x.dtype)))
    xs = xbc[..., :d_inner].reshape(b, s, n_heads, head_dim)
    Bm = xbc[..., d_inner:d_inner + d_state]                    # [B,S,N]
    Cm = xbc[..., d_inner + d_state:]                           # [B,S,N]

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"][None, None])       # [B,S,H]
    a_log = -jnp.exp(params["A_log"])[None, None] * dt          # log a_t <= 0

    q = min(chunk, s)
    assert s % q == 0, (s, q)
    nc = s // q

    def rs(t, *extra):  # [B, S, ...] -> [nc, B, Q, ...]
        return jnp.moveaxis(t.reshape(b, nc, q, *t.shape[2:]), 0, 1)

    xs_c, b_c, c_c = rs(xs), rs(Bm), rs(Cm)
    dt_c, al_c = rs(dt), rs(a_log)

    @jax.checkpoint
    def chunk_body(h_in, inputs):
        xck, bck, cck, dtk, alk = inputs          # [B,Q,...]
        l = jnp.cumsum(alk, axis=1)               # [B,Q,H] cumulative log a
        # intra-chunk: scores[q_,t] = C_q·B_t · exp(l_q - l_t) · dt_t, t<=q_
        cb = jnp.einsum("bqn,btn->bqt", cck.astype(jnp.float32),
                        bck.astype(jnp.float32))
        causal = jnp.tril(jnp.ones((q, q), bool))[None, :, :, None]
        # mask BEFORE exp: the upper triangle would overflow (l decreasing)
        ldiff = jnp.where(causal, l[:, :, None] - l[:, None, :], -jnp.inf)
        decay = jnp.exp(ldiff)                                  # [B,Q,Q,H]
        scores = cb[..., None] * decay * dtk[:, None, :, :]     # [B,Q,Q,H]
        y_intra = jnp.einsum("bqth,bthp->bqhp", scores,
                             xs_f := xck.astype(jnp.float32))
        # inter-chunk: y += C_t · exp(l_t) h_in
        y_inter = jnp.einsum("bqn,bqh,bhpn->bqhp", cck.astype(jnp.float32),
                             jnp.exp(l), h_in)
        # next chunk's incoming state
        tail = jnp.exp(l[:, -1:, :] - l)                        # [B,Q,H]
        s_chunk = jnp.einsum("bth,bth,btn,bthp->bhpn", tail, dtk,
                             bck.astype(jnp.float32), xs_f)
        h_out = jnp.exp(l[:, -1])[:, :, None, None] * h_in + s_chunk
        return h_out, y_intra + y_inter

    h0 = jnp.zeros((b, n_heads, head_dim, d_state), jnp.float32)
    _, ys = jax.lax.scan(chunk_body, h0, (xs_c, b_c, c_c, dt_c, al_c))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, n_heads, head_dim)
    y = y + params["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(b, s, d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm"])
    return y @ params["out_proj"].astype(x.dtype)


class MambaCache(NamedTuple):
    conv: jax.Array   # [B, conv_dim, K-1] last inputs
    h: jax.Array      # [B, H, P, N] ssm state (f32)


def init_mamba_cache(batch: int, d_model: int, d_state: int,
                     head_dim: int = 64, expand: int = 2,
                     dtype=jnp.bfloat16) -> MambaCache:
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    conv_dim = d_inner + 2 * d_state
    return MambaCache(conv=jnp.zeros((batch, conv_dim, _CONV_K - 1), dtype),
                      h=jnp.zeros((batch, n_heads, head_dim, d_state),
                                  jnp.float32))


def mamba_step(params: Params, cache: MambaCache, x: jax.Array, *,
               d_state: int, head_dim: int = 64
               ) -> Tuple[jax.Array, MambaCache]:
    """One-token recurrent step.  x: [B, 1, D]."""
    b, _, d = x.shape
    d_inner = params["out_proj"].shape[0]
    n_heads = d_inner // head_dim

    zxbcdt = x[:, 0] @ params["in_proj"].astype(x.dtype)
    z, xbc, dt = _split_proj(zxbcdt, d_inner, d_state, n_heads)
    # conv over (cached K-1 inputs, current)
    window = jnp.concatenate([cache.conv.astype(x.dtype),
                              xbc[:, :, None]], axis=-1)   # [B,C,K]
    conv_out = (window * params["conv_w"][None].astype(x.dtype)).sum(-1)
    xbc = jax.nn.silu(conv_out + params["conv_b"].astype(x.dtype))
    xs = xbc[..., :d_inner].reshape(b, n_heads, head_dim)
    Bm = xbc[..., d_inner:d_inner + d_state]
    Cm = xbc[..., d_inner + d_state:]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"][None])
    a = jnp.exp(-jnp.exp(params["A_log"])[None] * dt)          # [B,H]
    h = (a[:, :, None, None] * cache.h
         + jnp.einsum("bh,bhp,bn->bhpn", dt, xs.astype(jnp.float32),
                      Bm.astype(jnp.float32)))
    y = jnp.einsum("bhpn,bn->bhp", h, Cm.astype(jnp.float32))
    y = y + params["D"][None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(b, d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm"])
    out = (y @ params["out_proj"].astype(x.dtype))[:, None]
    new_cache = MambaCache(conv=window[:, :, 1:], h=h)
    return out, new_cache
