"""Transformer building blocks (pure JAX, pytree params).

Attention is implemented as a q-block scan with a statically-sized KV view
per block:

* full attention   — KV view = whole sequence (quadratic, memory bounded by
  ``q_block × S`` per step instead of ``S × S``),
* sliding window   — KV view = ``window + q_block`` slice positioned under
  the query block (sub-quadratic FLOPs, the Mixtral-style SWA used for the
  ``long_500k`` shapes).

Decode runs against a KV cache with an explicit per-slot position array, so
the same masking logic covers linear caches and ring buffers (SWA).
GQA never materializes repeated KV heads (grouped einsum).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist import constrain

__all__ = ["rms_norm", "rope", "attention", "decode_attention", "KVCache",
           "swiglu", "gelu_mlp", "init_linear", "init_rms"]

Params = Dict[str, jax.Array]


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    scale = jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (x32 * scale).astype(x.dtype) * gamma


def rope(x: jax.Array, positions: jax.Array, theta: float = 1e4) -> jax.Array:
    """Rotary embedding, split-half convention.  x: [..., S, H, D]."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-jnp.log(theta) * jnp.arange(half, dtype=jnp.float32)
                    / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs      # [..., S, half]
    cos = jnp.cos(ang)[..., None, :]                            # [..., S, 1, half]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


# ------------------------------------------------------------------ attention


def _block_attend(q: jax.Array, k: jax.Array, v: jax.Array,
                  q_pos: jax.Array, kv_pos: jax.Array,
                  window: int, kv_valid: Optional[jax.Array] = None
                  ) -> jax.Array:
    """Grouped-query attention over one q block and its KV view.

    q: [B, Sq, Hkv, G, D]; k/v: [B, Skv, Hkv, D];
    q_pos: [Sq]; kv_pos: [Skv] (slot positions, -1 = empty slot).
    """
    dh = q.shape[-1]
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", q, k,
                        preferred_element_type=jnp.float32)
    scores *= 1.0 / jnp.sqrt(dh)
    mask = kv_pos[None, :] <= q_pos[:, None]            # causal
    mask &= kv_pos[None, :] >= 0                        # slot written
    if window > 0:
        mask &= (q_pos[:, None] - kv_pos[None, :]) < window
    if kv_valid is not None:
        mask &= kv_valid[None, :]
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)
    return out


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              window: int = 0, q_block: int = 512,
              pos0: int = 0, impl: str = "blocked") -> jax.Array:
    """Causal (optionally sliding-window) attention over a full sequence.

    q: [B, S, Hq, D]; k/v: [B, S, Hkv, D] -> [B, S, Hq, D].
    impl='blocked': q-block scan with statically-shaped KV views — the
    full sequence (window=0) or a ``window + q_block`` slice (SWA), which
    is what makes long_500k prefill sub-quadratic for SWA models.
    impl='flash' (window=0 only): the Pallas online-softmax kernel — the
    score matrix never leaves VMEM (see kernels/flash_attention.py).
    """
    b, s, hq, dh = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    if impl == "flash" and window == 0 and s > 1:
        from repro.kernels import ops as kops
        from repro.dist import current_mesh, pspec, shard_map_compat
        qg = q.reshape(b, s, hkv, g, dh)
        qb_ = min(q_block, s)
        mesh = current_mesh()
        if mesh is not None and mesh.size > 1:
            # pallas_call is opaque to the SPMD partitioner — without
            # shard_map XLA replicates the operands across the mesh.
            # Shard batch (and kv heads when they divide |model|).
            h_ax = ("model" if hkv % mesh.shape.get("model", 1) == 0
                    else None)
            qs = pspec(("pod", "data"), None, h_ax, None, None)
            ks = pspec(("pod", "data"), None, h_ax, None)
            fn = shard_map_compat(
                lambda q_, k_, v_: kops.flash_attention(q_, k_, v_, qb_,
                                                        pos0),
                mesh, in_specs=(qs, ks, ks), out_specs=qs)
            out = fn(qg, k, v)
        else:
            out = kops.flash_attention(qg, k, v, qb_, pos0)
        return out.reshape(b, s, hq, dh)
    qb = min(q_block, s)
    n_blocks = s // qb
    assert s % qb == 0, (s, qb)
    qg = q.reshape(b, s, hkv, g, dh)

    if window > 0 and window + qb < s:
        kv_len = window + qb
    else:
        kv_len = s

    @jax.checkpoint
    def body(carry, i):
        # rematerialized: the [B,H,qb,kv] score/softmax tensors are
        # recomputed in the backward pass instead of being saved per block
        # (without this, residuals are n_blocks × B×H×qb×kv floats).
        q_start = i * qb
        qi = jax.lax.dynamic_slice_in_dim(qg, q_start, qb, axis=1)
        q_pos = pos0 + q_start + jnp.arange(qb)
        if kv_len == s:
            ki, vi = k, v
            kv_pos = pos0 + jnp.arange(s)
        else:
            start = jnp.clip(q_start + qb - kv_len, 0, s - kv_len)
            ki = jax.lax.dynamic_slice_in_dim(k, start, kv_len, axis=1)
            vi = jax.lax.dynamic_slice_in_dim(v, start, kv_len, axis=1)
            kv_pos = pos0 + start + jnp.arange(kv_len)
        oi = _block_attend(qi, ki, vi, q_pos, kv_pos, window)
        return carry, oi

    _, outs = jax.lax.scan(body, None, jnp.arange(n_blocks))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, n_blocks * qb, hkv, g, dh)
    return out.reshape(b, s, hq, dh)


# ------------------------------------------------------------------ KV cache


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class KVCache:
    """Linear or ring-buffer KV cache with explicit slot positions."""
    k: jax.Array          # [B, C, Hkv, D]
    v: jax.Array          # [B, C, Hkv, D]
    slot_pos: jax.Array   # [C] int32, -1 = empty
    pos: jax.Array        # scalar int32: number of tokens seen

    @classmethod
    def init(cls, batch: int, capacity: int, n_kv: int, head_dim: int,
             dtype=jnp.bfloat16, prefix: Tuple[int, ...] = ()) -> "KVCache":
        shape = (*prefix, batch, capacity, n_kv, head_dim)
        return cls(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
                   slot_pos=jnp.full((*prefix, capacity), -1, jnp.int32),
                   pos=jnp.zeros(prefix, jnp.int32))

    @property
    def capacity(self) -> int:
        return self.k.shape[-3]


def decode_attention(q: jax.Array, k_new: jax.Array, v_new: jax.Array,
                     cache: KVCache, *, window: int = 0
                     ) -> Tuple[jax.Array, KVCache]:
    """One-token decode: write (k_new, v_new) into the cache (ring-buffer
    write when the cache is smaller than the stream), attend over it.

    q: [B, 1, Hq, D]; k_new/v_new: [B, 1, Hkv, D].
    """
    b, _, hq, dh = q.shape
    hkv = k_new.shape[2]
    g = hq // hkv
    write = cache.pos % cache.capacity
    k = jax.lax.dynamic_update_slice_in_dim(cache.k, k_new.astype(cache.k.dtype),
                                            write, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache.v, v_new.astype(cache.v.dtype),
                                            write, axis=1)
    slot_pos = jax.lax.dynamic_update_slice_in_dim(
        cache.slot_pos, cache.pos[None], write, axis=0)
    qg = q.reshape(b, 1, hkv, g, dh)
    q_pos = cache.pos[None]
    out = _block_attend(qg, k, v, q_pos, slot_pos, window)
    new_cache = KVCache(k=k, v=v, slot_pos=slot_pos, pos=cache.pos + 1)
    return out.reshape(b, 1, hq, dh), new_cache


def prefill_into_cache(k: jax.Array, v: jax.Array, cache: KVCache
                       ) -> KVCache:
    """Write a full prefill's K/V into a fresh cache (capacity >= S)."""
    s = k.shape[1]
    cap = cache.capacity
    kk = cache.k.at[:, :s].set(k.astype(cache.k.dtype))
    vv = cache.v.at[:, :s].set(v.astype(cache.v.dtype))
    slot_pos = cache.slot_pos.at[:s].set(jnp.arange(s, dtype=jnp.int32))
    return KVCache(k=kk, v=vv, slot_pos=slot_pos,
                   pos=jnp.asarray(s, jnp.int32))


# ----------------------------------------------------------------------- MLP


def swiglu(x: jax.Array, w1: jax.Array, w3: jax.Array, w2: jax.Array
           ) -> jax.Array:
    h = jax.nn.silu(x @ w1) * (x @ w3)
    h = constrain(h, ("pod", "data"), None, "model")
    return h @ w2


def gelu_mlp(x: jax.Array, w1: jax.Array, w2: jax.Array) -> jax.Array:
    h = jax.nn.gelu(x @ w1)
    h = constrain(h, ("pod", "data"), None, "model")
    return h @ w2


# ---------------------------------------------------------------------- init


def init_linear(key: jax.Array, fan_in: int, fan_out: int,
                dtype=jnp.float32, std: Optional[float] = None) -> jax.Array:
    std = std if std is not None else fan_in ** -0.5
    return (jax.random.normal(key, (fan_in, fan_out), jnp.float32) * std
            ).astype(dtype)


def init_rms(dim: int, dtype=jnp.float32) -> jax.Array:
    return jnp.ones((dim,), dtype)
