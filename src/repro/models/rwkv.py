"""RWKV-6 "Finch" block (arXiv:2404.05892) — attention-free token mixing
with data-dependent per-channel decay.

Time-mix (WKV6), per head of size K=V=64:
    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    y_t = r_t · (S_{t-1} + diag(u) k_t^T v_t)
with w_t ∈ (0,1) produced from the token stream via a low-rank projection
(the "data-dependent decay" that distinguishes RWKV-6 from RWKV-5), plus the
usual token-shift interpolation on every projection input.  Channel-mix is
the squared-ReLU gated FFN.

Training runs a ``lax.scan`` over time on the [B,H,K,V] state (O(1) memory
in S); decode is the same body on one token.  The state recurrence makes the
``long_500k`` decode shape run at constant memory — no KV cache at all.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

__all__ = ["init_rwkv_params", "rwkv_forward", "rwkv_step", "RWKVCache",
           "init_rwkv_cache"]

Params = Dict[str, jax.Array]

_LORA = 64  # low-rank width of the decay projection


def init_rwkv_params(key: jax.Array, d_model: int, d_ff: int,
                     head_dim: int = 64, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 10)
    s = d_model ** -0.5
    n_heads = d_model // head_dim

    def lin(k, din, dout, scale=None):
        return (jax.random.normal(k, (din, dout), jnp.float32)
                * (scale if scale is not None else din ** -0.5)).astype(dtype)

    return {
        # time-mix
        "mix": (jax.random.uniform(ks[0], (5, d_model), jnp.float32)
                ).astype(dtype),                     # lerp weights r,k,v,w,g
        "w_r": lin(ks[1], d_model, d_model),
        "w_k": lin(ks[2], d_model, d_model),
        "w_v": lin(ks[3], d_model, d_model),
        "w_g": lin(ks[4], d_model, d_model),
        "w0": jnp.full((d_model,), -4.0, jnp.float32),
        "w_lora_a": lin(ks[5], d_model, _LORA, 0.01),
        "w_lora_b": lin(ks[6], _LORA, d_model, 0.01),
        "u": (jax.random.normal(ks[7], (n_heads, head_dim), jnp.float32)
              * 0.1).astype(jnp.float32),
        "ln_g": jnp.ones((d_model,), dtype),
        "w_o": lin(ks[8], d_model, d_model),
        # channel-mix
        "mix_c": (jax.random.uniform(ks[9], (2, d_model), jnp.float32)
                  ).astype(dtype),
        "c_k": lin(ks[0], d_model, d_ff),
        "c_v": lin(ks[1], d_ff, d_model),
        "c_r": lin(ks[2], d_model, d_model),
    }


def _token_shift(x: jax.Array, x_prev: jax.Array) -> jax.Array:
    """[B,S,D] -> previous-token tensor; x_prev is the t=-1 row [B,D]."""
    return jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)


def _wkv_inputs(params: Params, x: jax.Array, xp: jax.Array, head_dim: int):
    """Shared projection math for scan/step.  x, xp: [B,T,D]."""
    b, t, d = x.shape
    h = d // head_dim
    mix = params["mix"].astype(x.dtype)
    lerp = lambda i: x + (xp - x) * mix[i][None, None]
    r = (lerp(0) @ params["w_r"].astype(x.dtype)).reshape(b, t, h, head_dim)
    k = (lerp(1) @ params["w_k"].astype(x.dtype)).reshape(b, t, h, head_dim)
    v = (lerp(2) @ params["w_v"].astype(x.dtype)).reshape(b, t, h, head_dim)
    g = jax.nn.silu(lerp(4) @ params["w_g"].astype(x.dtype))
    # data-dependent decay (low-rank)
    wx = lerp(3)
    w = (params["w0"][None, None]
         + jnp.tanh(wx.astype(jnp.float32) @ params["w_lora_a"].astype(jnp.float32))
         @ params["w_lora_b"].astype(jnp.float32))
    w = jnp.exp(-jnp.exp(w)).reshape(b, t, h, head_dim)   # decay in (0,1)
    return r, k, v, g, w


def _wkv_scan(r, k, v, w, u, s0):
    """Sequential WKV (reference / decode path).

    r,k,v,w: [B,T,H,K]; u: [H,K]; s0: [B,H,K,V] -> y [B,T,H,V], s_T.
    """

    def body(s, inp):
        rt, kt, vt, wt = inp    # [B,H,K] / [B,H,V]
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        y = jnp.einsum("bhk,bhkv->bhv", rt, s + u[None, :, :, None] * kv)
        s = wt[..., None] * s + kv
        return s, y

    xs = tuple(jnp.moveaxis(t, 1, 0).astype(jnp.float32)
               for t in (r, k, v, w))
    s_t, ys = jax.lax.scan(body, s0, xs)
    return jnp.moveaxis(ys, 0, 1), s_t


def _wkv_chunked(r, k, v, w, u, s0, chunk: int = 128):
    """Chunked WKV (GLA-style block decomposition) — the training path.

    Equivalent to ``_wkv_scan`` (property-tested) but scans over T/chunk
    chunks instead of T steps, so the backward pass stores T/chunk states
    instead of T — the linear-attention analogue of Mamba-2's SSD chunking.

    Inside a chunk (log-space cumulative decay L_t = Σ_{i<=t} log w_i):
      y_t = (r_t ⊙ e^{L_{t-1}})·S_0                       (inter)
          + Σ_{i<t} (r_t ⊙ e^{L_{t-1}-L_i})·k_i · v_i      (intra)
          + (r_t·(u ⊙ k_t)) v_t                            (bonus diag)
      S' = e^{L_Q} ⊙ S_0 + Σ_i (k_i ⊙ e^{L_Q-L_i}) v_iᵀ
    """
    b, t, h, dk = r.shape
    q = min(chunk, t)
    if t % q:
        return _wkv_scan(r, k, v, w, u, s0)   # ragged fallback
    nc = t // q

    def rs(x):
        return jnp.moveaxis(
            x.reshape(b, nc, q, h, dk).astype(jnp.float32), 1, 0)

    rc, kc, vc, wc = rs(r), rs(k), rs(v), rs(w)

    @jax.checkpoint
    def body(s, inp):
        rt, kt, vt, wt = inp                       # [B,Q,H,K]
        logw = jnp.log(jnp.maximum(wt, 1e-38))
        L = jnp.cumsum(logw, axis=1)               # [B,Q,H,K]
        Lprev = L - logw                           # L_{t-1}
        q_dec = rt * jnp.exp(Lprev)                # r_t ⊙ e^{L_{t-1}}
        k_dec = kt * jnp.exp(-L)                   # k_i ⊙ e^{-L_i}
        # intra-chunk scores (strictly lower-triangular) + bonus diagonal
        scores = jnp.einsum("bqhk,bihk->bhqi", q_dec, k_dec)
        tri = jnp.tril(jnp.ones((q, q), bool), k=-1)
        scores = jnp.where(tri[None, None], scores, 0.0)
        diag = jnp.einsum("bqhk,hk,bqhk->bqh", rt, u, kt)
        y = (jnp.einsum("bhqi,bihv->bqhv", scores, vt)
             + diag[..., None] * vt
             + jnp.einsum("bqhk,bhkv->bqhv", q_dec, s))
        # chunk-final state
        k_tail = kt * jnp.exp(L[:, -1:, :, :] - L)
        s = (jnp.exp(L[:, -1])[..., None] * s
             + jnp.einsum("bihk,bihv->bhkv", k_tail, vt))
        return s, y

    s_t, ys = jax.lax.scan(body, s0, (rc, kc, vc, wc))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, t, h, dk)
    return y, s_t


def _group_norm(y: jax.Array, gamma: jax.Array, head_dim: int) -> jax.Array:
    """Per-head LayerNorm on [B,T,H,V], flattened back to [B,T,D]."""
    mu = y.mean(-1, keepdims=True)
    var = y.var(-1, keepdims=True)
    yn = (y - mu) * jax.lax.rsqrt(var + 1e-5)
    b, t, h, v = y.shape
    return yn.reshape(b, t, h * v) * gamma.astype(jnp.float32)


def rwkv_time_mix(params: Params, x: jax.Array, x_prev: jax.Array,
                  s0: jax.Array, head_dim: int = 64, chunk: int = 128
                  ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x: [B,S,D]; returns (out, last_x, s_T)."""
    xp = _token_shift(x, x_prev)
    r, k, v, g, w = _wkv_inputs(params, x, xp, head_dim)
    if x.shape[1] > 1:
        y, s_t = _wkv_chunked(r, k, v, w, params["u"], s0, chunk)
    else:
        y, s_t = _wkv_scan(r, k, v, w, params["u"], s0)
    y = _group_norm(y, params["ln_g"], head_dim).astype(x.dtype)
    out = (y * g) @ params["w_o"].astype(x.dtype)
    return out, x[:, -1], s_t


def rwkv_channel_mix(params: Params, x: jax.Array, x_prev: jax.Array
                     ) -> Tuple[jax.Array, jax.Array]:
    xp = _token_shift(x, x_prev)
    mix = params["mix_c"].astype(x.dtype)
    xk = x + (xp - x) * mix[0][None, None]
    xr = x + (xp - x) * mix[1][None, None]
    kk = jnp.square(jax.nn.relu(xk @ params["c_k"].astype(x.dtype)))
    out = jax.nn.sigmoid(xr @ params["c_r"].astype(x.dtype)) \
        * (kk @ params["c_v"].astype(x.dtype))
    return out, x[:, -1]


class RWKVCache(NamedTuple):
    tm_x: jax.Array     # [B, D] last token seen by time-mix
    cm_x: jax.Array     # [B, D] last token seen by channel-mix
    s: jax.Array        # [B, H, K, V] wkv state (f32)


def init_rwkv_cache(batch: int, d_model: int, head_dim: int = 64,
                    dtype=jnp.bfloat16) -> RWKVCache:
    h = d_model // head_dim
    return RWKVCache(tm_x=jnp.zeros((batch, d_model), dtype),
                     cm_x=jnp.zeros((batch, d_model), dtype),
                     s=jnp.zeros((batch, h, head_dim, head_dim), jnp.float32))


def rwkv_forward(params: Params, x: jax.Array, ln1: jax.Array,
                 ln2: jax.Array, head_dim: int = 64) -> jax.Array:
    """Full RWKV block (time-mix + channel-mix, pre-RMSNorm residual)."""
    from .layers import rms_norm
    b, s, d = x.shape
    h = d // head_dim
    s0 = jnp.zeros((b, h, head_dim, head_dim), jnp.float32)
    zero = jnp.zeros((b, d), x.dtype)
    tm, _, _ = rwkv_time_mix(params, rms_norm(x, ln1), zero, s0, head_dim)
    x = x + tm
    cm, _ = rwkv_channel_mix(params, rms_norm(x, ln2), zero)
    return x + cm


def rwkv_step(params: Params, cache: RWKVCache, x: jax.Array,
              ln1: jax.Array, ln2: jax.Array, head_dim: int = 64
              ) -> Tuple[jax.Array, RWKVCache]:
    """One-token step.  x: [B, 1, D]."""
    from .layers import rms_norm
    xn = rms_norm(x, ln1)
    tm, tm_x, s_t = rwkv_time_mix(params, xn, cache.tm_x.astype(x.dtype),
                                  cache.s, head_dim)
    x = x + tm
    xn = rms_norm(x, ln2)
    cm, cm_x = rwkv_channel_mix(params, xn, cache.cm_x.astype(x.dtype))
    x = x + cm
    return x, RWKVCache(tm_x=tm_x.astype(cache.tm_x.dtype),
                        cm_x=cm_x.astype(cache.cm_x.dtype), s=s_t)
