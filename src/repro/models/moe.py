"""Sort-based top-k routed MoE (Mixtral / Llama-4-Scout style).

Static-shape dispatch, routed **per batch row** (vmap over the batch dim):
each row's token assignments are argsorted by expert, each expert takes up
to ``capacity`` tokens per row (surplus dropped — GShard-style), expert FFNs
run as batched einsums over the [B, E, C, D] buffer, and outputs are
combined back with the router weights.

Why per-row: the batch dim is the data-parallel sharded dim.  Routing each
row independently keeps the sort / cumsum / scatter local to a shard under
SPMD (no cross-device argsort), which is exactly how group-limited routing
works in production MoE systems (GShard "groups", MaxText's per-batch
dispatch).  Compiled FLOPs are E·C·(3·D·F)·2 ≈ active FLOPs × cap-factor.

Sharding: expert weights [E, D, F] are laid out P(None, "data", "model")
(experts replicated over the mesh, each expert FSDP+TP sharded) because the
assigned configs have E ∈ {8, 16} < |model|=16; see DESIGN.md §5.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.dist import constrain, current_mesh, current_policy

__all__ = ["moe_ffn", "init_moe_params", "router_assignment"]


def init_moe_params(key: jax.Array, d_model: int, d_ff: int, n_experts: int,
                    dtype=jnp.float32) -> Dict[str, jax.Array]:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s_in = d_model ** -0.5
    s_ff = d_ff ** -0.5
    return {
        "router": (jax.random.normal(k1, (d_model, n_experts), jnp.float32)
                   * s_in).astype(dtype),
        "w1": (jax.random.normal(k2, (n_experts, d_model, d_ff), jnp.float32)
               * s_in).astype(dtype),
        "w3": (jax.random.normal(k3, (n_experts, d_model, d_ff), jnp.float32)
               * s_in).astype(dtype),
        "w2": (jax.random.normal(k4, (n_experts, d_ff, d_model), jnp.float32)
               * s_ff).astype(dtype),
    }


def router_assignment(logits: jax.Array, top_k: int
                      ) -> Tuple[jax.Array, jax.Array]:
    """[T, E] router logits -> (weights [T, K], experts [T, K]).

    Softmax over the selected experts (Mixtral convention).
    """
    gate_logits, experts = jax.lax.top_k(logits, top_k)
    weights = jax.nn.softmax(gate_logits.astype(jnp.float32), axis=-1)
    return weights, experts


def _capacity(tokens: int, n_experts: int, top_k: int, factor: float) -> int:
    cap = int(max(1, -(-tokens * top_k // n_experts)) * factor)
    return -(-cap // 8) * 8 if cap > 8 else cap


def _routing_indices(logits: jax.Array, top_k: int, capacity: int):
    """Pure index math for one row (vmapped; no data movement).

    Gather-only formulation: slot (e, c) holds sorted-assignment
    ``starts[e] + c``, so dispatch is ``xf[token_for_slot]`` and combine is
    ``yf[slot_for_assignment]`` — no scatter in the forward pass at all
    (XLA lowers scatters with index tensors as large as the data; gathers
    are cheap and their transposes fuse into the backward).
    """
    t, e = logits.shape
    _, experts = jax.lax.top_k(logits, top_k)                # [T, K]
    flat_expert = experts.reshape(t * top_k)
    order = jnp.argsort(flat_expert, stable=True)            # [T*K]
    inv_order = jnp.argsort(order, stable=True)
    hist = jnp.sum(jax.nn.one_hot(flat_expert, e, dtype=jnp.int32), axis=0)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(hist)[:-1]])
    # dispatch side: slot (e, c) <- sorted position starts[e] + c
    ec = jnp.arange(e * capacity)
    e_of_slot = ec // capacity
    c_of_slot = ec % capacity
    sorted_idx = jnp.minimum(starts[e_of_slot] + c_of_slot, t * top_k - 1)
    token_for_slot = order[sorted_idx] // top_k              # [E*C]
    slot_valid = c_of_slot < hist[e_of_slot]
    # combine side: assignment (t, k) -> its slot (or overflow)
    pos = inv_order - starts[flat_expert]
    keep = pos < capacity
    slot_for_assign = jnp.where(
        keep, flat_expert * capacity + pos, 0)               # [T*K]
    return token_for_slot, slot_valid, slot_for_assign, keep, experts


def moe_ffn(x: jax.Array, params: Dict[str, jax.Array], *, top_k: int,
            capacity_factor: float = 1.25
            ) -> Tuple[jax.Array, jax.Array]:
    """x: [B, S, D] -> (out [B, S, D], aux_loss scalar).

    Dispatch/combine index math is vmapped per row; the expert matmuls are
    explicit batched einsums with sharding constraints so the batch dim
    stays data-parallel-sharded through expert compute (without the
    constraints XLA has been observed to replicate the batch around the
    FSDP-sharded expert weights).
    """
    b, s, d = x.shape
    e = params["router"].shape[1]
    capacity = _capacity(s, e, top_k, capacity_factor)
    x = constrain(x, ("pod", "data"), None, None)

    logits = jnp.einsum("bsd,de->bse", x, params["router"].astype(x.dtype))
    # load-balancing aux loss, computed PER GROUP (= batch row) as in
    # Switch: E * Σ_e f_e(row)·p_e(row), then averaged over rows.  The
    # per-group form decomposes over microbatches (grad-accum identity).
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top1 = jnp.argmax(logits, axis=-1)
    fe = jax.nn.one_hot(top1, e, dtype=jnp.float32).mean(1)     # [B, E]
    aux = (e * jnp.sum(fe * probs.mean(1), axis=-1)).mean()

    token_for_slot, slot_valid, slot_for_assign, keep, experts = jax.vmap(
        lambda lg: _routing_indices(lg, top_k, capacity))(logits)
    gate_logits = jnp.take_along_axis(logits, experts, axis=-1)   # [B,S,K]
    weights = jax.nn.softmax(gate_logits.astype(jnp.float32), axis=-1)

    # expert parallelism ('ep' policy, E % |model| == 0): the capacity
    # buffer is sharded over experts on the model axis (the dispatch
    # gather becomes an all-to-all) and the expert einsums are rank-local
    mesh = current_mesh()
    ep = (current_policy() == "ep" and mesh is not None
          and mesh.shape.get("model", 1) > 1
          and e % mesh.shape.get("model", 1) == 0)
    e_ax = "model" if ep else None
    f_ax = None if ep else "model"

    # dispatch: pure gather into the capacity buffer
    xe = jnp.take_along_axis(x, token_for_slot[..., None], axis=1)
    xe = jnp.where(slot_valid[..., None], xe, 0)
    xe = xe.reshape(b, e, capacity, d)
    xe = constrain(xe, ("pod", "data"), e_ax, None, None)    # [B, E, C, D]

    h = (jax.nn.silu(jnp.einsum("becd,edf->becf", xe,
                                params["w1"].astype(x.dtype)))
         * jnp.einsum("becd,edf->becf", xe, params["w3"].astype(x.dtype)))
    h = constrain(h, ("pod", "data"), e_ax, None, f_ax)      # [B, E, C, F]
    ye = jnp.einsum("becf,efd->becd", h, params["w2"].astype(x.dtype))
    ye = constrain(ye, ("pod", "data"), e_ax, None, None)
    yf = ye.reshape(b, e * capacity, d)

    # combine: gather each assignment's slot output, weighted sum over K
    ya = jnp.take_along_axis(yf, slot_for_assign[..., None], axis=1)
    ya = ya.reshape(b, s, top_k, d)
    wk = (weights * keep.reshape(b, s, top_k)).astype(x.dtype)
    out = jnp.einsum("bskd,bsk->bsd", ya, wk)
    return constrain(out, ("pod", "data"), None, None), aux
