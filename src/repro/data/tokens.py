"""Host-side token pipeline for LM training — the paper's two-stage
prefetching (Section IV-B) applied to the language-model substrate.

Stage "load": produce the next batch in host memory (here: synthetic
seeded token generation standing in for tokenization + host-RAM reads).
Stage "transfer": ``jax.device_put`` onto the target sharding (H2D).
Both stages run in their own threads with bounded queues (depth =
prefetch window), overlapping with device compute exactly like the GNN
Feature Loader / Data Transfer stages.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import numpy as np

from repro.core.pipeline import PipelineItem, PrefetchPipeline, Stage
from repro.models import ModelConfig

__all__ = ["TokenPipeline"]


@dataclasses.dataclass
class TokenPipeline:
    cfg: ModelConfig
    batch: int
    seq: int
    seed: int = 0
    depth: int = 2                 # TFP prefetch window (0 = sequential)
    sharding: Optional[jax.sharding.Sharding] = None

    def _make_host_batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(self.seed + step)
        cfg = self.cfg
        if cfg.frontend == "audio_stub":
            emb = rng.standard_normal(
                (self.batch, self.seq, cfg.d_model)).astype(np.float32)
            labels = rng.integers(0, cfg.vocab, (self.batch, self.seq),
                                  dtype=np.int32)
            return {"embeds": emb, "labels": labels}
        if cfg.frontend == "vision_stub":
            nv = cfg.vision_tokens
            toks = rng.integers(0, cfg.vocab, (self.batch, self.seq - nv),
                                dtype=np.int32)
            vis = rng.standard_normal(
                (self.batch, nv, cfg.d_model)).astype(np.float32)
            return {"tokens": toks, "vision_embeds": vis, "labels": toks}
        # zipf-ish synthetic text: heavy-tailed token ids
        z = rng.zipf(1.3, (self.batch, self.seq)).astype(np.int64)
        toks = (z % self.cfg.vocab).astype(np.int32)
        return {"tokens": toks, "labels": toks}

    def __iter__(self) -> Iterator[Dict[str, jax.Array]]:
        return self.batches(10**9)

    def batches(self, num_steps: int) -> Iterator[Dict[str, jax.Array]]:
        def load(item: PipelineItem) -> PipelineItem:
            item.payload = self._make_host_batch(item.seq)
            return item

        def transfer(item: PipelineItem) -> PipelineItem:
            put = (lambda a: jax.device_put(a, self.sharding)
                   if self.sharding is not None else jax.device_put(a))
            item.payload = {k: put(v) for k, v in item.payload.items()}
            return item

        pipe = PrefetchPipeline([Stage("load", load),
                                 Stage("transfer", transfer)],
                                depth=self.depth)
        items = (PipelineItem(seq=i, payload=None) for i in range(num_steps))
        for item in pipe.run(items):
            yield item.payload
