#!/usr/bin/env bash
# Static analysis, findings-as-errors (rule catalog: docs/static-analysis.md).
#
#   ./scripts/lint.sh             # analyze everything under src/
#   ./scripts/lint.sh --changed   # analyze everything (cross-file rules need
#                                 # the whole project) but REPORT only files
#                                 # touched since origin/main
#
# Two steps:
#  1. repro.analysis — the repo-specific RPR rule set (guarded-by lock
#     discipline, Pallas kernel invariants, determinism/accounting).
#  2. mypy — strict on the annotated core (repro.analysis, repro.graph.faults,
#     repro.core.protocol; per-module config in pyproject.toml).  The step is
#     SKIPPED with a notice when mypy is not installed: the pinned CI image
#     carries it, minimal local environments may not, and the RPR step must
#     still gate either way.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" == "--changed" ]]; then
    base="$(git merge-base HEAD origin/main 2>/dev/null \
            || git rev-parse HEAD~1 2>/dev/null \
            || echo "")"
    changed=()
    if [[ -n "$base" ]]; then
        while IFS= read -r f; do
            [[ -f "$f" ]] && changed+=("$f")
        done < <(git diff --name-only "$base" -- 'src/*.py' 'src/**/*.py')
    fi
    if [[ ${#changed[@]} -eq 0 ]]; then
        echo "lint: no python files under src/ changed since ${base:-HEAD~1}"
    else
        python -m repro.analysis src --report-only "${changed[@]}"
    fi
else
    python -m repro.analysis src
fi

if python -c "import mypy" 2>/dev/null; then
    python -m mypy --config-file pyproject.toml src/repro
else
    echo "lint: mypy not installed — skipping the type-check step" \
         "(RPR analysis above still gated)"
fi

echo "lint: OK"
