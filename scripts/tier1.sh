#!/usr/bin/env bash
# Tier-1 verification: the full test suite plus a ~30 s cache-ablation
# smoke bench (asserts the >= 2x feature-byte reduction at a 20% cache
# fraction and cached/uncached loss equivalence).
#
#   ./scripts/tier1.sh            # everything
#   ./scripts/tier1.sh --fast     # skip the 'slow' subprocess-compile tests
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

MARK=()
if [[ "${1:-}" == "--fast" ]]; then
    MARK=(-m "not slow")
fi

# ${MARK[@]+...} guards the empty-array expansion under `set -u` on bash < 4.4
python -m pytest -x -q ${MARK[@]+"${MARK[@]}"}
python -m benchmarks.fig_cache_ablation --smoke
echo "tier1: OK"
