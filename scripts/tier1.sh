#!/usr/bin/env bash
# Tier-1 verification: the full test suite plus three smoke benches —
#  * cache-ablation (~30 s): >= 2x feature-byte reduction at a 20% cache
#    fraction and cached/uncached loss equivalence,
#  * cache-refresh (~30 s): on a drifting-hub trace the dynamic refresh
#    policy's steady-state hit rate >= the static policy's with strictly
#    fewer shipped bytes, and trainer losses bit-identical with refresh
#    on/off (versioned in-flight consistency),
#  * out-of-core (~60 s): mmap gather parity with the dense backend in a
#    tempdir (cleaned up on exit), the spill writer's one-partition
#    buffered-rows bound, a bounded gather working set, and mmap/dense
#    loss bit-identity,
#  * background I/O (~60 s): window-prefetch on/off disk-tier sweep —
#    prefetch on must show strictly lower load-stage stall, page-cache
#    residency stays under the window-LRU bound, and trainer losses are
#    bit-identical across the {prefetch, async_refresh} 4-config matrix,
#  * kernel overlap (~60 s): pipelined (multi-buffered DMA) combine and
#    scatter-update kernels at depths 2/4 bit-identical to the
#    single-buffered depth-1 path and the jnp oracles (f32 + bf16,
#    aliased slots), VMEM scratch within budget, no-worse wall time on
#    interpret-mode CPU, and e2e trainer losses bit-identical across
#    pipeline depths.
#
#  * sharded plane (~60 s): sharded vs replicated feature cache at equal
#    per-device capacity — the union gather must ship strictly fewer
#    host->device bytes than per-trainer dedup at n_accel >= 2, the
#    n_accel=4 cell must clear the >= 1.5x shipped-byte reduction, and
#    sharded/replicated losses must be bit-identical,
#
#  * autotune (~90 s): closed-DRM-loop gate — a knob-misconfigured run
#    (no prefetch, one-window LRU, skewed stage threads) with the
#    model-predictive knob autotuner ON must converge to within 15% of
#    the hand-tuned steady-state iteration time, with losses
#    bit-identical to the static-knob twin and >= 1 accepted move,
#
#  * chaos suite (~30 s, hard 300 s timeout): deterministic fault
#    injection against the whole trainer — transient storage faults with
#    bit-identical losses, prefetcher death with graceful degradation,
#    and the pipeline stage-watchdog.  Runs as its OWN step so a
#    fault-handling regression that wedges cannot hang the main suite:
#    the timeout converts a hang into a failure.
#
#   ./scripts/tier1.sh            # everything
#   ./scripts/tier1.sh --fast     # skip the 'slow' subprocess-compile tests
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

MARK=(-m "not chaos")
if [[ "${1:-}" == "--fast" ]]; then
    MARK=(-m "not slow and not chaos")
fi

# static analysis first: a lock-discipline or kernel-invariant finding is
# cheaper to surface than the test failure (or silent race) it predicts
./scripts/lint.sh

# ${MARK[@]+...} guards the empty-array expansion under `set -u` on bash < 4.4
python -m pytest -x -q ${MARK[@]+"${MARK[@]}"}
timeout 300 python -m pytest -x -q -m chaos
python -m benchmarks.fig_cache_ablation --smoke
python -m benchmarks.fig_cache_ablation --smoke-refresh
python -m benchmarks.bench_outofcore --smoke
python -m benchmarks.bench_outofcore --smoke-prefetch
python -m benchmarks.bench_kernel_overlap --smoke
python -m benchmarks.bench_shard --smoke
python -m benchmarks.bench_autotune --smoke
echo "tier1: OK"
